"""Host-looped lazy executor vs the on-device executor — WALL-CLOCK.

``bench_executor.py`` established that the lazy path computes a fraction
of the eager path's scores.  This benchmark measures what the score count
cannot: the host stage loop's orchestration tax — one device->host sync,
one host compaction and one fresh gather upload PER STAGE — versus
``DeviceExecutor``, which fuses the whole stage loop (scoring, decide,
compaction, early exit) into one jit'd ``lax.while_loop`` (DESIGN.md §5).

Both paths run the identical Pallas kernels at the identical block size,
so the delta is orchestration, not kernel arithmetic.  Per (batch size,
alpha) cell we report steady-state wall seconds (compiles excluded; best
of ``repeats``), the scores each path computed, and the jit trace count
of the device program (the static-shape design promises exactly 1).

Timing protocol: EXPERIMENTS.md §Wall-clock.  Outputs land in
``benchmarks/results/device_executor_<dataset>.json`` and — as the start
of the repo's perf trajectory — ``BENCH_executor.json`` at the repo root.

Acceptance: the on-device executor beats the host loop at batch >= 1024.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import gbt_ensemble_for, save_rows
from repro.core import CascadePlan, evaluate_cascade, fit_qwyc
from repro.kernels import ops
from repro.api.registry import get_backend
from repro.kernels.device_executor import (
    DevicePlan,
    tree_stage_scorer,
)

REPO_ROOT = pathlib.Path(__file__).parent.parent

ALPHAS = (0.005, 0.02, 0.1)
BATCH_SIZES = (256, 1024, 2048)


def _tile_rows(x: np.ndarray, n: int) -> np.ndarray:
    reps = -(-n // x.shape[0])
    return np.tile(x, (reps, 1))[:n]


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def run(
    dataset: str = "adult",
    T: int = 100,
    depth: int = 5,
    scale: float = 0.25,
    chunk_t: int = 8,
    block_n: int = 128,
    alphas=ALPHAS,
    batch_sizes=BATCH_SIZES,
    repeats: int = 3,
) -> list[dict]:
    gbt, F_tr, F_te, beta, ds = gbt_ensemble_for(dataset, T, depth, scale)
    st = gbt.stacked()
    rows = []
    for alpha in alphas:
        m = fit_qwyc(F_tr, beta=beta, alpha=alpha)
        plan = CascadePlan.from_qwyc(m, chunk_t=chunk_t)
        dplan = DevicePlan.from_plan(plan)

        # cascade-ordered stacked params, permuted once at plan build
        of = np.asarray(st["feats"])[m.order]
        ot = np.asarray(st["thrs"])[m.order]
        ol = np.asarray(st["leaves"])[m.order]
        of_j, ot_j, ol_j = jnp.asarray(of), jnp.asarray(ot), jnp.asarray(ol)

        device_backend = get_backend("device")
        executors: dict[int, tuple] = {}

        for n in batch_sizes:
            # block size scales with batch (same value for BOTH paths):
            # bigger batches amortize per-block dispatch over wider blocks
            bn = min(256, max(block_n, n // 8))
            if bn not in executors:
                scorer = tree_stage_scorer(dplan, of, ot, ol, block_n=bn)
                executors[bn] = (
                    device_backend.make_executor(dplan, scorer=scorer, block_n=bn),
                    set(),
                )
            dex, shapes_seen = executors[bn]
            shapes_seen.add(-(-n // bn) * bn)  # buffer capacity for this batch
            x_np = _tile_rows(
                np.asarray(ds.x_test, dtype=np.float32), n
            )
            F_sub = _tile_rows(np.asarray(F_te, dtype=np.float64), n)
            ev = evaluate_cascade(m, F_sub)
            exit_rate = float((ev["exit_step"] < T).mean())
            xj = jnp.asarray(x_np)

            def producer(rows_, t0, t1, _bn=bn):
                return np.asarray(
                    ops.gbt_scores(
                        of_j, ot_j, ol_j, xj, block_n=_bn,
                        t0=t0, t1=t1, rows=jnp.asarray(np.asarray(rows_)),
                    )
                )

            def host(_bn=bn):
                return ops.score_and_decide(producer, plan, n, block_n=_bn)

            def device():
                return dex.run(x_np, n)

            res_h = host()  # warmup/compile both paths before timing
            res_d = device()
            # both paths must agree with the host cascade oracle
            assert np.array_equal(res_h.decisions, ev["decisions"])
            assert np.array_equal(res_h.exit_step, ev["exit_step"])
            assert np.array_equal(res_d.decisions, ev["decisions"])
            assert np.array_equal(res_d.exit_step, ev["exit_step"])

            host_s = _best_of(host, repeats)
            device_s = _best_of(device, repeats)

            rows.append(
                {
                    "experiment": f"device_executor_{dataset}",
                    "alpha": alpha,
                    "n": n,
                    "T": T,
                    "chunk_t": chunk_t,
                    "block_n": bn,
                    "exit_rate": exit_rate,
                    "mean_models": float(ev["exit_step"].mean()),
                    "host_s": host_s,
                    "device_s": device_s,
                    "speedup": host_s / max(device_s, 1e-12),
                    "host_stages": len(res_h.chunk_stats),
                    "device_stages": len(res_d.chunk_stats),
                    "scores_host": res_h.scores_computed,
                    "scores_device": res_d.scores_computed,
                    # exactly one jit trace per (N, T, chunk_t): the
                    # executor's trace count must equal the number of
                    # distinct batch shapes it has served
                    "device_traces": dex.traces,
                    "device_shapes": len(shapes_seen),
                    # acceptance: on-device wins wall-clock at batch >= 1024
                    "device_wins": bool(device_s < host_s),
                }
            )
    save_rows(f"device_executor_{dataset}", rows)
    _write_root_summary(dataset, rows)
    return rows


def _write_root_summary(dataset: str, rows: list[dict]) -> None:
    """BENCH_executor.json — the repo-root perf-trajectory artifact.

    ``bench_sharded.py`` owns the file's ``"sharded"`` section and
    ``bench_streaming.py`` its ``"streaming"`` section; preserve both
    across rewrites so suite ordering can't drop them."""
    path = REPO_ROOT / "BENCH_executor.json"
    prior = json.loads(path.read_text()) if path.exists() else {}
    big = [r for r in rows if r["n"] >= 1024]
    summary = {
        "bench": "device_executor",
        "dataset": dataset,
        "protocol": "EXPERIMENTS.md §Wall-clock",
        "rows": rows,
        "headline": {
            "batch>=1024_device_wins": bool(all(r["device_wins"] for r in big)),
            "batch>=1024_median_speedup": float(
                np.median([r["speedup"] for r in big])
            )
            if big
            else None,
            "one_trace_per_batch_shape": bool(
                all(r["device_traces"] == r["device_shapes"] for r in rows)
            ),
        },
    }
    for section in ("sharded", "streaming"):
        if section in prior:
            summary[section] = prior[section]
    path.write_text(json.dumps(summary, indent=1))


if __name__ == "__main__":
    for r in run():
        print(
            f"alpha={r['alpha']:<6} n={r['n']:<5} exit_rate={r['exit_rate']:.2f} "
            f"host={r['host_s']*1e3:7.1f}ms device={r['device_s']*1e3:7.1f}ms "
            f"speedup={r['speedup']:.2f}x "
            f"traces={r['device_traces']}/{r['device_shapes']} "
            f"wins={r['device_wins']}"
        )
