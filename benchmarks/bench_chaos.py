"""Chaos benchmark: fault injection against the guarded serving stack
(DESIGN.md §10, EXPERIMENTS.md §Chaos protocol).

Three scenarios, each driven deterministically from ``CHAOS_SEED`` via
``repro.testing.faults`` so every run reproduces bit-for-bit:

1. **device loss** — a sharded server loses its mesh devices mid-serving
   (``drop_device`` + unbounded sharded wave failures).  The degradation
   ladder must retry, fall exactly ``sharded -> device``, and every
   verdict must match a host-oracle server bit-for-bit.  Recovery
   latency is 0 flushes by construction (the ladder re-runs the failed
   wave on the lower rung inside the same flush); what the row records
   is the retry count the backoff policy consumed before falling.
2. **1% poison** — ``poison_fraction=0.01`` of requests carry NaN/inf.
   Every poisoned row must come back ``quarantined``; every clean row's
   verdict AND per-row billing (``models_evaluated``) must equal the
   unpoisoned run's.
3. **drift watchdog** — a drifted trace (rows where the calibrated
   cascade disagrees with the full ensemble, found by an oracle pass)
   must trip the sequential alarm; the degraded full-cascade policy then
   drives the statistic down on clean traffic and the watchdog re-arms.
   A clean-only control run must never alarm.  Recovery latency is
   reported both in flushes (``recovery_step - alarm_step``) and in
   stage steps (flushes x T: every degraded flush runs the full
   cascade).

Results land in ``benchmarks/results/chaos_<dataset>.json`` and merge
into the repo-root ``BENCH_executor.json`` under the ``"chaos"`` key
(schema-validated by the CI bench-artifact job).  The counters here are
additive diagnostics — deliberately NOT part of ``perf_gate``'s billing
baseline, which locks the no-fault path only.
"""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gbt_ensemble_for, save_rows
from repro.core import fit_qwyc
from repro.api.backends import BackoffPolicy
from repro.kernels import ops
from repro.serving.engine import QWYCServer
from repro.testing import FaultPlan

REPO_ROOT = pathlib.Path(__file__).parent.parent

CHAOS_SEED = 1863  # every scenario derives its faults from this
ALPHA = 0.02
NO_SLEEP = {"backoff": BackoffPolicy(retries=2), "sleep": lambda s: None}


def _world(dataset: str, T: int, depth: int, scale: float):
    gbt, F_tr, _F_te, beta, ds = gbt_ensemble_for(dataset, T, depth, scale)
    st = gbt.stacked()

    def score_fn(x):
        return np.asarray(
            ops.gbt_scores(
                st["feats"], st["thrs"], st["leaves"], jnp.asarray(x)
            )
        )

    qwyc = fit_qwyc(F_tr, beta=beta, alpha=ALPHA)
    return qwyc, score_fn, ds


def _tile_rows(x: np.ndarray, n: int) -> np.ndarray:
    reps = -(-n // x.shape[0])
    return np.tile(x, (reps, 1))[:n]


def _serve(srv, X):
    for row in X:
        srv.submit(row)
    return srv.drain()


def _scenario_device_loss(qwyc, score_fn, X) -> dict:
    n_dev = len(jax.devices())
    if n_dev < 2:
        return {
            "experiment": "chaos_device_loss",
            "seed": CHAOS_SEED,
            "ok": True,
            "skipped": f"{n_dev} device(s) < 2 (run under XLA_FLAGS="
            "--xla_force_host_platform_device_count=4)",
        }
    oracle = QWYCServer(qwyc, score_fn=score_fn, batch_size=64, backend="kernel")
    want = _serve(oracle, X)

    srv = QWYCServer(
        qwyc, score_fn=score_fn, batch_size=32, backend="kernel",
        exec_backend="sharded", backend_opts={"shards": 2}, **NO_SLEEP,
    )
    with FaultPlan(
        seed=CHAOS_SEED, drop_device=True, wave_failures=10_000,
        wave_fail_backend="sharded",
    ) as fp:
        got = _serve(srv, X)

    falls = [
        f"{e.from_backend}->{e.to_backend}"
        for e in srv.stats.degradation_events
        if e.from_backend != e.to_backend
    ]
    parity = len(got) == len(want) and all(
        g["decision"] == w["decision"]
        and g["models_evaluated"] == w["models_evaluated"]
        for g, w in zip(got, want)
    )
    return {
        "experiment": "chaos_device_loss",
        "seed": CHAOS_SEED,
        "n_requests": len(X),
        "faults_injected": int(fp.injected["waves"]),
        "falls": falls,
        "landed_on": srv.exec.name,
        "retries_before_fall": int(NO_SLEEP["backoff"].retries),
        "recovery_latency_flushes": 0,  # ladder recovers within the flush
        "parity_with_host_oracle": bool(parity),
        "ok": bool(parity and falls == ["sharded->device"]),
    }


def _scenario_poison(qwyc, score_fn, X) -> dict:
    clean_srv = QWYCServer(
        qwyc, score_fn=score_fn, batch_size=64, backend="kernel"
    )
    want = _serve(clean_srv, X)

    plan = FaultPlan(seed=CHAOS_SEED, poison_fraction=0.01, poison_mode="mix")
    Xp, mask = plan.poison(X)
    srv = QWYCServer(qwyc, score_fn=score_fn, batch_size=64, backend="kernel")
    got = _serve(srv, Xp)

    n_poisoned = int(mask.sum())
    all_quarantined = all(
        got[i].get("quarantined", False) for i in range(len(X)) if mask[i]
    )
    clean_unchanged = all(
        not got[i].get("quarantined", False)
        and got[i]["decision"] == want[i]["decision"]
        and got[i]["models_evaluated"] == want[i]["models_evaluated"]
        for i in range(len(X))
        if not mask[i]
    )
    return {
        "experiment": "chaos_poison_1pct",
        "seed": CHAOS_SEED,
        "n_requests": len(X),
        "poisoned": n_poisoned,
        "quarantined": int(srv.stats.quarantined),
        "all_poisoned_quarantined": bool(
            all_quarantined and srv.stats.quarantined == n_poisoned
        ),
        "clean_rows_unchanged": bool(clean_unchanged),
        "ok": bool(
            all_quarantined
            and srv.stats.quarantined == n_poisoned
            and clean_unchanged
        ),
    }


def _drift_split(qwyc, score_fn, pool):
    F = score_fn(pool)
    srv = QWYCServer(qwyc, score_fn=score_fn, batch_size=64, backend="kernel")
    out = _serve(srv, pool)
    dec = np.array([r["decision"] for r in out])
    full = F.sum(axis=1) >= qwyc.beta
    return pool[dec != full], pool[dec == full]


def _scenario_watchdog(qwyc, score_fn, ds, flush=32) -> dict:
    pool = _tile_rows(np.asarray(ds.x_test, np.float32), 1024)
    drift, clean = _drift_split(qwyc, score_fn, pool)
    T = qwyc.T

    # control: clean-only traffic must never alarm
    control = QWYCServer(
        qwyc, score_fn=score_fn, batch_size=flush, backend="kernel",
        watchdog=True,
    )
    _serve(control, clean[: flush * 8])
    clean_no_alarm = control.stats.watchdog_alarms == 0

    # drifted trace -> alarm; then clean traffic under the degraded
    # policy -> recovery
    srv = QWYCServer(
        qwyc, score_fn=score_fn, batch_size=flush, backend="kernel",
        watchdog=True,
    )
    drift_batch = _tile_rows(drift, flush) if len(drift) else drift
    _serve(srv, drift_batch)
    alarmed = srv.stats.watchdog_alarms >= 1
    alarm_step = srv._watchdog.alarm_step
    recovered = False
    for _ in range(40):
        if srv.stats.watchdog_state == "ok":
            recovered = True
            break
        _serve(srv, clean[:flush])
    rec_flushes = (
        (srv._watchdog.recovery_step - alarm_step)
        if (recovered and alarm_step is not None)
        else None
    )
    return {
        "experiment": "chaos_watchdog_drift",
        "seed": CHAOS_SEED,
        "drift_rows": int(len(drift)),
        "flush_rows": flush,
        "alarm_fired": bool(alarmed),
        "alarm_step": alarm_step,
        "recovered": bool(recovered),
        "recovery_latency_flushes": rec_flushes,
        "recovery_latency_stage_steps": (
            rec_flushes * T if rec_flushes is not None else None
        ),
        "clean_trace_no_alarm": bool(clean_no_alarm),
        "ok": bool(alarmed and recovered and clean_no_alarm),
    }


def run(dataset="adult", T=60, depth=5, scale=0.25, n_requests=256):
    qwyc, score_fn, ds = _world(dataset, T, depth, scale)
    X = _tile_rows(np.asarray(ds.x_test, np.float32), n_requests)
    rows = [
        _scenario_device_loss(qwyc, score_fn, X),
        _scenario_poison(qwyc, score_fn, X),
        _scenario_watchdog(qwyc, score_fn, ds),
    ]
    save_rows(f"chaos_{dataset}", rows)
    _merge_root_summary(dataset, rows)
    return rows


def _merge_root_summary(dataset: str, rows: list[dict]) -> None:
    """Add/replace the ``"chaos"`` section of BENCH_executor.json (the
    device-executor bench owns the rest of the file; this section is
    preserved across its rewrites like ``"streaming"``)."""
    path = REPO_ROOT / "BENCH_executor.json"
    doc = json.loads(path.read_text()) if path.exists() else {}
    by = {r["experiment"]: r for r in rows}
    dl = by.get("chaos_device_loss", {})
    wd = by.get("chaos_watchdog_drift", {})
    doc["chaos"] = {
        "protocol": "EXPERIMENTS.md §Chaos protocol",
        "dataset": dataset,
        "seed": CHAOS_SEED,
        "rows": rows,
        "headline": {
            "all_scenarios_ok": bool(all(r.get("ok") for r in rows)),
            "device_loss_parity": dl.get("parity_with_host_oracle"),
            "poison_quarantined_all": by["chaos_poison_1pct"][
                "all_poisoned_quarantined"
            ],
            "poison_clean_rows_unchanged": by["chaos_poison_1pct"][
                "clean_rows_unchanged"
            ],
            "watchdog_alarmed_and_recovered": bool(
                wd.get("alarm_fired") and wd.get("recovered")
            ),
            "watchdog_recovery_latency_flushes": wd.get(
                "recovery_latency_flushes"
            ),
            "watchdog_recovery_latency_stage_steps": wd.get(
                "recovery_latency_stage_steps"
            ),
        },
    }
    path.write_text(json.dumps(doc, indent=1))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes (CI)")
    ap.add_argument("--dataset", default="adult")
    args = ap.parse_args()
    kw = (
        dict(T=40, scale=0.1, n_requests=128)
        if args.quick
        else dict(T=60, scale=0.25, n_requests=256)
    )
    for r in run(args.dataset, **kw):
        status = "ok" if r.get("ok") else r.get("skipped", "FAILED")
        print(f"{r['experiment']}: {status}")
